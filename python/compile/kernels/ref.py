"""Pure-jnp oracle for the Generalized Margin Propagation (GMP) solve.

The GMP primitive (paper eq. 9) computes, for every batch row ``b``, the
scalar ``h[b]`` that satisfies

    sum_j g(X[b, j] - h[b]) = C

for a monotone rectifier-like shape ``g`` (``g(0)=0``, ``g' >= 0``,
``g(-inf)=0``).  The left-hand side is strictly decreasing in ``h`` wherever
it is positive, so the solution is unique and bracketable:

    at  h = max_j X[b,j]            ->  LHS = 0        <= C
    at  h = max_j X[b,j] - C - 4w   ->  LHS >= C       (w = knee width)

because every supported shape satisfies ``g(z) >= z`` for ``z >= 0``
(ReLU attains equality, softplus exceeds it).  Sixty bisection steps on a
bracket of width ``C + 4w`` give ~2^-60 relative localization — far below
both f32 resolution and analog mismatch noise.

This module is the *correctness oracle*: a straightforward, obviously-right
implementation that the Pallas kernel (``gmp.py``) and the rust solver
(``rust/src/sac/gmp.rs``) are tested against.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

#: number of bisection iterations used by oracle, kernel and rust solver.
GMP_ITERS = 60

#: shape identifiers, shared with the Pallas kernel.
SHAPE_RELU = 0
SHAPE_SOFTPLUS = 1


def g_shape(z, shape: int = SHAPE_RELU, width: float = 0.0):
    """Evaluate the GMP shape function ``g``.

    ``SHAPE_RELU``      g(z) = max(z, 0)                       (paper eq. 3)
    ``SHAPE_SOFTPLUS``  g(z) = w * log(1 + exp(z / w))         (WI device shape)

    ``width`` is the knee width ``w`` of the soft shape; ignored for ReLU.
    The softplus shape models what a weak-inversion transistor's forward
    current actually implements (paper Sec. III-A): exponential tail below
    the knee, linear above it.
    """
    if shape == SHAPE_RELU:
        return jnp.maximum(z, 0.0)
    if shape == SHAPE_SOFTPLUS:
        w = jnp.asarray(width, dtype=z.dtype)
        return w * jnp.logaddexp(jnp.zeros_like(z), z / w)
    raise ValueError(f"unknown shape id {shape}")


def gmp_solve_ref(x, c, shape: int = SHAPE_RELU, width: float = 0.05,
                  iters: int = GMP_ITERS):
    """Reference GMP solve.

    Args:
      x:     ``[..., M]`` spline-expanded inputs (last axis reduced).
      c:     scalar normalization constant ``C > 0``.
      shape: ``SHAPE_RELU`` or ``SHAPE_SOFTPLUS``.
      width: knee width of the soft shape (ignored for ReLU).
      iters: bisection iterations.

    Returns:
      ``h`` with shape ``x.shape[:-1]`` solving ``sum_j g(x_j - h) = C``.
    """
    x = jnp.asarray(x)
    c = jnp.asarray(c, dtype=x.dtype)
    hi = jnp.max(x, axis=-1)
    pad = 4.0 * width if shape != SHAPE_RELU else 0.0
    lo = hi - c - pad

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        s = jnp.sum(g_shape(x - mid[..., None], shape, width), axis=-1)
        gt = s > c  # residual still above C -> root is to the right
        lo = jnp.where(gt, mid, lo)
        hi = jnp.where(gt, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return 0.5 * (lo + hi)


def gmp_residual(x, h, c, shape: int = SHAPE_RELU, width: float = 0.05):
    """``sum_j g(x_j - h) - C`` — zero at the true solution."""
    return jnp.sum(g_shape(x - h[..., None], shape, width), axis=-1) - c


def gmp_grad_ref(x, h, shape: int = SHAPE_RELU, width: float = 0.05):
    """Implicit-function gradient of the GMP solve.

    Differentiating ``sum_j g(x_j - h) = C``:

        dh = sum_j g'(x_j - h) dx_j / sum_k g'(x_k - h)

    For ReLU the derivative is the winner indicator normalised by the
    winner count (the paper's eq. 22/23 have exactly this structure).
    """
    z = x - h[..., None]
    if shape == SHAPE_RELU:
        gp = (z > 0.0).astype(x.dtype)
    else:
        gp = jax.nn.sigmoid(z / width)
    denom = jnp.sum(gp, axis=-1, keepdims=True)
    return gp / jnp.maximum(denom, 1e-30)
