//! Process migration: the headline claim end-to-end.  Take the *same*
//! trained S-AC network and the same standard cells, "fabricate" them at
//! 180 nm and at 7 nm (device-exact tier for the cells, table tier for the
//! network), and show that both the cell shapes and the classification
//! accuracy survive the migration — with zero design changes.
//!
//! Run: `cargo run --release --example process_migration` (needs
//! `make artifacts`)

use sac::analysis::dc;
use sac::cells::activations::CellKind;
use sac::cells::CircuitCorner;
use sac::data::Dataset;
use sac::nn;
use sac::pdk::{regime::Regime, CMOS180, FINFET7};
use sac::sac::TableModel;
use sac::util::table::Table;

fn main() -> anyhow::Result<()> {
    // 1. cell-level migration
    let zs = dc::grid(-2.0, 2.0, 25);
    let mut t = Table::new(
        "cell-shape migration 180nm → 7nm (normalized max deviation)",
        &["cell", "WI", "MI", "SI"],
    );
    for kind in [CellKind::Relu, CellKind::Phi1, CellKind::Softplus] {
        let mut row = vec![kind.name().to_string()];
        for regime in sac::pdk::regime::Regime::all() {
            let a = dc::normalize(&dc::sweep_cell(
                kind,
                &CircuitCorner::new(&CMOS180, regime),
                &zs,
            ));
            let b = dc::normalize(&dc::sweep_cell(
                kind,
                &CircuitCorner::new(&FINFET7, regime),
                &zs,
            ));
            let (mx, _) = dc::curve_deviation(&a, &b);
            row.push(format!("{mx:.4}"));
        }
        t.row(row);
    }
    println!("{}", t.render());

    // 2. network-level migration (Table IV's punchline)
    let artifacts = sac::runtime::default_artifacts_dir();
    let net = match nn::load_net(&artifacts, "xor") {
        Ok(n) => n,
        Err(e) => {
            println!("(skipping network migration: {e} — run `make artifacts`)");
            return Ok(());
        }
    };
    let ds = Dataset::load_sacd(&artifacts.join("xor_test.bin"))?;
    let mut t2 = Table::new(
        "XOR network accuracy after migration [%]",
        &["corner", "accuracy"],
    );
    t2.row(vec![
        "software (float)".into(),
        format!("{:.1}", net.acc_sw * 100.0),
    ]);
    for (name, node) in [("180nm WI", &CMOS180), ("7nm WI", &FINFET7)] {
        let tm = TableModel::calibrate(
            if node.name == "cmos180" { &CMOS180 } else { &FINFET7 },
            Regime::WeakInversion,
            27.0,
        );
        let cm = nn::evaluate(&net, || Box::new(tm.clone()), &ds, ds.n, 4);
        t2.row(vec![name.into(), format!("{:.1}", cm.accuracy() * 100.0)]);
    }
    println!("{}", t2.render());
    println!("→ same weights, same cells, two processes: accuracy preserved");
    Ok(())
}
