//! Activation zoo: sweep every Fig. 6 standard cell across process nodes,
//! biasing regimes and temperatures; write CSVs and a compact robustness
//! report — the full Sec. IV characterization in one binary.
//!
//! Run: `cargo run --release --example activation_zoo [-- <outdir>]`

use std::path::PathBuf;

use sac::analysis::dc;
use sac::cells::activations::CellKind;
use sac::cells::CircuitCorner;
use sac::pdk::{regime::Regime, ProcessNode};
use sac::util::table::{write_xy_csv, Table};

fn main() -> anyhow::Result<()> {
    let outdir = PathBuf::from(
        std::env::args().nth(1).unwrap_or_else(|| "results/zoo".into()),
    );
    std::fs::create_dir_all(&outdir)?;
    let zs = dc::grid(-2.0, 2.0, 33);

    let mut report = Table::new(
        "activation-cell robustness (max normalized deviation from 180nm/WI/27C)",
        &["cell", "vs 7nm", "vs SI", "vs 125C", "vs -45C"],
    );

    for kind in CellKind::all() {
        let base = CircuitCorner::new(
            ProcessNode::by_name("180nm").unwrap(),
            Regime::WeakInversion,
        );
        let y0 = dc::normalize(&dc::sweep_cell(kind, &base, &zs));

        let mut devs = Vec::new();
        let corners: Vec<(&str, CircuitCorner)> = vec![
            (
                "7nm",
                CircuitCorner::new(
                    ProcessNode::by_name("7nm").unwrap(),
                    Regime::WeakInversion,
                ),
            ),
            (
                "SI",
                CircuitCorner::new(
                    ProcessNode::by_name("180nm").unwrap(),
                    Regime::StrongInversion,
                ),
            ),
            (
                "125C",
                CircuitCorner::new(
                    ProcessNode::by_name("180nm").unwrap(),
                    Regime::WeakInversion,
                )
                .at_temp(125.0),
            ),
            (
                "-45C",
                CircuitCorner::new(
                    ProcessNode::by_name("180nm").unwrap(),
                    Regime::WeakInversion,
                )
                .at_temp(-45.0),
            ),
        ];
        let mut all_series: Vec<(String, Vec<f64>)> =
            vec![("base".to_string(), y0.clone())];
        for (name, corner) in &corners {
            let y = dc::normalize(&dc::sweep_cell(kind, corner, &zs));
            let (mx, _) = dc::curve_deviation(&y0, &y);
            devs.push(mx);
            all_series.push((name.to_string(), y));
        }
        let refs: Vec<(&str, &[f64])> = all_series
            .iter()
            .map(|(n, v)| (n.as_str(), v.as_slice()))
            .collect();
        write_xy_csv(&outdir.join(format!("zoo_{}.csv", kind.name())), "x", &zs, &refs)?;
        report.row(vec![
            kind.name().to_string(),
            format!("{:.4}", devs[0]),
            format!("{:.4}", devs[1]),
            format!("{:.4}", devs[2]),
            format!("{:.4}", devs[3]),
        ]);
    }
    println!("{}", report.render());
    println!("CSV sweeps written to {}", outdir.display());
    Ok(())
}
