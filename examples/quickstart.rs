//! Quickstart: build an S-AC standard cell, sweep it at two process nodes,
//! and print the (normalized) transfer curves — the paper's core claim in
//! 30 lines.
//!
//! Run: `cargo run --release --example quickstart`

use sac::analysis::dc;
use sac::cells::activations::CellKind;
use sac::cells::CircuitCorner;
use sac::pdk::{regime::Regime, CMOS180, FINFET7};
use sac::util::table::ascii_plot;

fn main() {
    let zs = dc::grid(-2.0, 2.0, 41);

    // the same sigmoid (φ2) standard cell, device-exact, at two nodes
    let cell = CellKind::Phi2;
    let at_180 = CircuitCorner::new(&CMOS180, Regime::WeakInversion);
    let at_7 = CircuitCorner::new(&FINFET7, Regime::WeakInversion);

    let y180 = dc::normalize(&dc::sweep_cell(cell, &at_180, &zs));
    let y7 = dc::normalize(&dc::sweep_cell(cell, &at_7, &zs));

    println!(
        "S-AC '{}' cell — planar CMOS 180nm vs FinFET 7nm (WI):\n",
        cell.name()
    );
    print!(
        "{}",
        ascii_plot(&[("180nm", &y180[..]), ("7nm", &y7[..])], 12, 64)
    );

    let (max_dev, mean_dev) = dc::curve_deviation(&y180, &y7);
    println!(
        "\ncross-process deviation: max {:.4}, mean {:.4} of full scale",
        max_dev, mean_dev
    );
    println!("→ the same cell, unchanged, migrates 180nm → 7nm (paper Fig. 7)");
}
