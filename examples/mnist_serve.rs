//! END-TO-END driver (DESIGN.md deliverable): load the trained S-AC digit
//! classifier exported by the AOT pipeline, serve batched classification
//! requests through the coordinator on the native runtime, report accuracy
//! + latency/throughput, and cross-check one batch against the circuit-tier
//! golden path.
//!
//! This proves the three layers compose: the GMP solve is inside the
//! executed graph, the coordinator batches and executes it, and the
//! device-level simulator agrees with the compiled fast path.
//!
//! Run: `make artifacts && cargo run --release --example mnist_serve`

use std::time::Instant;

use sac::cells::multiplier::Multiplier;
use sac::coordinator::InferenceServer;
use sac::data::Dataset;
use sac::nn;
use sac::pdk::{regime::Regime, CMOS180};
use sac::runtime::{default_artifacts_dir, Runtime};
use sac::sac::TableModel;

fn main() -> anyhow::Result<()> {
    let artifacts = default_artifacts_dir();
    let rt = Runtime::new(&artifacts)?;
    println!("backend: {}", rt.platform());

    // ---- fast path: the exported S-AC network -------------------------
    let t_load = Instant::now();
    let mut server = InferenceServer::new(&rt, "digits")?;
    println!(
        "loaded digits_mlp in {:.2}s  (net {:?}, batch {})",
        t_load.elapsed().as_secs_f64(),
        server.engine.net.sizes,
        server.batcher.batch_size
    );

    let ds = Dataset::load_sacd(&artifacts.join("digits_test.bin"))?;
    let n = ds.n; // full 1000-image test set (paper scores 1000 images)
    for i in 0..n {
        server.submit(ds.row(i).to_vec());
    }
    let results = server.drain()?;
    let correct = results
        .iter()
        .filter(|&&(id, pred, _)| pred == ds.y[id as usize] as usize)
        .count();
    println!(
        "\nfast path (native): accuracy {}/{} = {:.1}%",
        correct,
        n,
        correct as f64 / n as f64 * 100.0
    );
    println!("  {}", server.metrics.report());

    // ---- golden path: table-tier circuit evaluation on a sample -------
    let sample = 32;
    let net = nn::load_net(&artifacts, "digits")?;
    let tm = TableModel::calibrate(&CMOS180, Regime::WeakInversion, 27.0);
    let t_gold = Instant::now();
    let m = Multiplier::calibrate(&tm, net.splines, net.c);
    let mut agree = 0;
    for i in 0..sample {
        let logits = nn::forward(&net, &tm, &m, ds.row(i));
        let pred = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(j, _)| j)
            .unwrap();
        let fast_pred = results.iter().find(|r| r.0 == i as u64).unwrap().1;
        if pred == fast_pred {
            agree += 1;
        }
    }
    println!(
        "\ngolden path (circuit table-tier, 180nm WI): {}/{} predictions agree with the fast path ({:.1}s)",
        agree,
        sample,
        t_gold.elapsed().as_secs_f64()
    );
    assert!(
        agree as f64 / sample as f64 > 0.85,
        "fast path and golden path diverged"
    );
    println!("→ all three layers compose; record in EXPERIMENTS.md");
    Ok(())
}
